"""G-RandomRing payload kernel (L1, Pallas).

HPCC G-RandomRing Bandwidth measures the per-process bandwidth of a ring
communication pattern over a *random* rank permutation — the paper
classifies it as *network intensive*.  On a single accelerator the network
is the simulator's concern (rust/src/perfmodel); the payload we AOT-compile
is the ring's local compute: each logical rank combines its buffer with the
buffer received from its ring predecessor.

Layout: ``buf`` is (P, N) — one row per logical MPI rank.  ``perm`` is the
ring permutation (rank i receives from ``perm[i]``).  Each grid step
produces one rank's row; the (unblocked) input is row-gathered with a
dynamic slice, which on real TPU is the remote-DMA receive.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ring_kernel(perm_ref, buf_ref, out_ref):
    """One ring step for rank ``i``: ``out[i] = 0.5 * (buf[i] + buf[perm[i]])``."""
    i = pl.program_id(0)
    src = perm_ref[i]
    mine = buf_ref[pl.dslice(i, 1), :]
    theirs = buf_ref[pl.dslice(src, 1), :]
    out_ref[...] = 0.5 * (mine + theirs)


@jax.jit
def ring_exchange(buf: jax.Array, perm: jax.Array) -> jax.Array:
    """One random-ring exchange+combine over rank-major ``buf`` (P, N)."""
    p, n = buf.shape
    if perm.shape != (p,):
        raise ValueError(f"perm shape {perm.shape} != ({p},)")
    return pl.pallas_call(
        _ring_kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((p, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, n), buf.dtype),
        interpret=True,
    )(perm, buf)


def bytes_on_wire(shape: tuple[int, int], itemsize: int = 4) -> int:
    """Each rank sends and receives one row per exchange."""
    p, n = shape
    return 2 * p * n * itemsize
