"""MiniFE payload kernel (L1, Pallas).

MiniFE assembles and solves an unstructured implicit finite-element system
with CG; its flop/byte hot spot is the sparse mat-vec.  On a structured
hexahedral mesh (the miniFE default, nx=ny=nz) the assembled operator acts
like a 27-point stencil; we implement the mat-vec as a blocked 7/27-point
Laplacian-style stencil over a 3-D grid — the paper classifies MiniFE as
*CPU and memory intensive*, which is exactly a stencil's roofline position.

TPU mapping: the grid is blocked into z-slabs; each grid step loads a slab
plus one-plane halos into VMEM and writes the interior plane.  Halos are
expressed by passing the full (padded) array unblocked and slicing per grid
step with ``pl.dsl`` loads — on real TPU this becomes a manual HBM->VMEM DMA
schedule; under ``interpret=True`` it is a plain gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# z-slab thickness per grid step; a slab of (BZ+2, ny+2, nx+2) fp32 for
# typical ny=nx=64 is (6*66*66*4) B ~ 105 KiB — well inside VMEM.
BZ = 4

# 7-point Laplacian weights (center, +-x, +-y, +-z) of the assembled
# miniFE operator on a uniform hex mesh.
CENTER = 6.0
OFF = -1.0


def _stencil_kernel(xp_ref, y_ref, *, bz: int):
    """One z-slab of ``y = A x`` for the 7-point operator.

    ``xp_ref`` is the full zero-padded input (nz+2, ny+2, nx+2), read with an
    explicit halo window; ``y_ref`` is the (bz, ny, nx) output slab.
    """
    k = pl.program_id(0)
    ny = y_ref.shape[1]
    nx = y_ref.shape[2]
    # Load the slab + z halos: rows [k*bz, k*bz + bz + 2) of the padded grid.
    slab = xp_ref[pl.dslice(k * bz, bz + 2), :, :]
    c = slab[1:-1, 1:-1, 1:-1]
    y_ref[...] = (
        CENTER * c
        + OFF * slab[:-2, 1:-1, 1:-1]
        + OFF * slab[2:, 1:-1, 1:-1]
        + OFF * slab[1:-1, :-2, 1:-1]
        + OFF * slab[1:-1, 2:, 1:-1]
        + OFF * slab[1:-1, 1:-1, :-2]
        + OFF * slab[1:-1, 1:-1, 2:]
    )


@functools.partial(jax.jit, static_argnames=("bz",))
def stencil_matvec(x: jax.Array, *, bz: int = BZ) -> jax.Array:
    """7-point stencil mat-vec ``y = A x`` with zero (Dirichlet) boundaries.

    ``x`` has shape (nz, ny, nx) with ``nz % bz == 0``.
    """
    nz, ny, nx = x.shape
    if nz % bz:
        raise ValueError(f"nz={nz} does not tile by bz={bz}")
    xp = jnp.pad(x, 1)
    return pl.pallas_call(
        functools.partial(_stencil_kernel, bz=bz),
        grid=(nz // bz,),
        in_specs=[pl.BlockSpec(xp.shape, lambda k: (0, 0, 0))],
        out_specs=pl.BlockSpec((bz, ny, nx), lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), x.dtype),
        interpret=True,
    )(xp)


def flops(shape: tuple[int, int, int]) -> int:
    """7 multiplies + 6 adds per interior point."""
    nz, ny, nx = shape
    return 13 * nz * ny * nx
