"""EP-STREAM payload kernel (L1, Pallas).

The HPCC EP-STREAM benchmark measures sustainable per-process memory
bandwidth with the triad loop ``a[i] = b[i] + scalar * c[i]`` — the paper
classifies it as *memory-bandwidth intensive*.  On TPU the analogue is a
VPU-bound streaming kernel: wide lane-aligned blocks moved HBM->VMEM,
touched exactly once, written back.  There is no reuse, so the BlockSpec
schedule *is* the optimisation: (8, 1024) blocks match the (8, 128) VPU
lane layout and keep DMA transfers long.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# (rows, lanes) per block: 8 sublanes x 1024 lanes x 4 B = 32 KiB per
# operand per step — long enough DMAs to saturate HBM, tiny VMEM footprint.
BROWS = 8
BLANES = 1024


def _triad_kernel(b_ref, c_ref, s_ref, a_ref):
    """One block of the STREAM triad: ``a = b + s * c``.

    ``s_ref`` is a (1, 1) block broadcast to every grid step (scalar operand
    kept in SMEM on real TPU).
    """
    a_ref[...] = b_ref[...] + s_ref[0, 0] * c_ref[...]


@functools.partial(jax.jit, static_argnames=("brows", "blanes"))
def triad(
    b: jax.Array,
    c: jax.Array,
    scalar: jax.Array,
    *,
    brows: int = BROWS,
    blanes: int = BLANES,
) -> jax.Array:
    """STREAM triad ``b + scalar * c`` over 2-D arrays.

    ``b`` and ``c`` must share a shape ``(R, L)`` with ``R % brows == 0``
    and ``L % blanes == 0``; ``scalar`` is a (1, 1) array.
    """
    if b.shape != c.shape:
        raise ValueError(f"shape mismatch: {b.shape} vs {c.shape}")
    r, l = b.shape
    if r % brows or l % blanes:
        raise ValueError(f"shape ({r},{l}) does not tile by ({brows},{blanes})")
    scalar = jnp.asarray(scalar, b.dtype).reshape(1, 1)
    return pl.pallas_call(
        _triad_kernel,
        grid=(r // brows, l // blanes),
        in_specs=[
            pl.BlockSpec((brows, blanes), lambda i, j: (i, j)),
            pl.BlockSpec((brows, blanes), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((brows, blanes), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, l), b.dtype),
        interpret=True,
    )(b, c, scalar)


def bytes_moved(shape: tuple[int, int], itemsize: int = 4) -> int:
    """Triad traffic: read b, read c, write a (3 streams)."""
    n = shape[0] * shape[1]
    return 3 * n * itemsize
