"""L1 Pallas payload kernels for the five paper benchmarks.

One module per HPC benchmark the paper schedules (HPCC EP-DGEMM, EP-STREAM,
G-FFT, G-RandomRing, and MiniFE); ``ref`` holds the pure-jnp oracles.
All kernels run under ``interpret=True`` — see DESIGN.md §Hardware-Adaptation.
"""

from . import dgemm, fft, ref, ring, stencil, stream  # noqa: F401
