"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package has its reference semantics defined here in
straight-line jnp; ``python/tests`` sweeps shapes/dtypes with hypothesis and
asserts allclose between kernel and oracle.  These functions are also what
the L2 model's unit tests compare full step functions against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dgemm(a: jax.Array, b: jax.Array) -> jax.Array:
    """fp32-accumulated matmul."""
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def triad(b: jax.Array, c: jax.Array, scalar) -> jax.Array:
    """STREAM triad ``b + scalar * c``."""
    return b + jnp.asarray(scalar, b.dtype) * c


def stencil_matvec(x: jax.Array) -> jax.Array:
    """7-point Laplacian-style mat-vec with zero boundaries."""
    xp = jnp.pad(x, 1)
    c = xp[1:-1, 1:-1, 1:-1]
    return (
        6.0 * c
        - xp[:-2, 1:-1, 1:-1]
        - xp[2:, 1:-1, 1:-1]
        - xp[1:-1, :-2, 1:-1]
        - xp[1:-1, 2:, 1:-1]
        - xp[1:-1, 1:-1, :-2]
        - xp[1:-1, 1:-1, 2:]
    )


def ring_exchange(buf: jax.Array, perm: jax.Array) -> jax.Array:
    """out[i] = 0.5 * (buf[i] + buf[perm[i]])."""
    return 0.5 * (buf + buf[perm, :])


def butterfly(a_re, a_im, b_re, b_im, w_re, w_im):
    """Radix-2 butterfly in planar complex form."""
    a = a_re + 1j * a_im
    b = b_re + 1j * b_im
    w = w_re + 1j * w_im
    t = a + w * b
    u = a - w * b
    return (
        jnp.real(t).astype(a_re.dtype),
        jnp.imag(t).astype(a_re.dtype),
        jnp.real(u).astype(a_re.dtype),
        jnp.imag(u).astype(a_re.dtype),
    )


def fft(x_re: jax.Array, x_im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Full FFT oracle via jnp.fft over a planar-complex 1-D signal."""
    y = jnp.fft.fft(x_re + 1j * x_im)
    return jnp.real(y).astype(x_re.dtype), jnp.imag(y).astype(x_re.dtype)
