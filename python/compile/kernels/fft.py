"""G-FFT payload kernel (L1, Pallas).

HPCC G-FFT measures a distributed 1-D FFT whose transpose phase is a global
all-to-all — the paper classifies it as *network intensive*.  The network
side lives in the simulator; the local flop hot spot is the radix-2
butterfly, implemented here as a Pallas kernel over planar complex data
(separate real/imag arrays — Pallas interpret mode has no complex refs).

One call computes one decimation-in-time stage for the *whole* signal:
given the stage's (half, M)-shaped even/odd operands and per-row twiddles,
it produces the (half, M) top and bottom halves.  The L2 model
(``model.fft_step``) composes ``log2(n)`` stages Stockham-style, doing the
(cheap, layout-only) interleave with jnp reshapes between calls, and is
verified against ``jnp.fft.fft``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _butterfly_kernel(
    ar_ref, ai_ref, br_ref, bi_ref, wr_ref, wi_ref, tr_ref, ti_ref, ur_ref, ui_ref
):
    """Radix-2 butterfly: ``t = a + w*b``, ``u = a - w*b`` (planar complex)."""
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    # w * b, complex multiply in planar form.
    wbr = wr * br - wi * bi
    wbi = wr * bi + wi * br
    tr_ref[...] = ar + wbr
    ti_ref[...] = ai + wbi
    ur_ref[...] = ar - wbr
    ui_ref[...] = ai - wbi


@jax.jit
def butterfly(
    a_re: jax.Array,
    a_im: jax.Array,
    b_re: jax.Array,
    b_im: jax.Array,
    w_re: jax.Array,
    w_im: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One radix-2 stage over (half, M) operands; twiddles broadcast per row.

    Returns ``(t_re, t_im, u_re, u_im)`` with ``t = a + w b``, ``u = a - w b``.
    """
    if a_re.shape != b_re.shape:
        raise ValueError(f"operand shape mismatch: {a_re.shape} vs {b_re.shape}")
    half, m = a_re.shape
    if w_re.shape != (half, 1):
        raise ValueError(f"twiddle shape {w_re.shape} != ({half}, 1)")
    shape = jax.ShapeDtypeStruct((half, m), a_re.dtype)
    full = pl.BlockSpec((half, m), lambda: (0, 0))
    tw = pl.BlockSpec((half, 1), lambda: (0, 0))
    return pl.pallas_call(
        _butterfly_kernel,
        in_specs=[full, full, full, full, tw, tw],
        out_specs=(full, full, full, full),
        out_shape=(shape, shape, shape, shape),
        interpret=True,
    )(a_re, a_im, b_re, b_im, w_re, w_im)


def flops(n: int) -> int:
    """Complex FFT flop count: 5 n log2 n (standard radix-2 accounting)."""
    import math

    return int(5 * n * math.log2(n))
