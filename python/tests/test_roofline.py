"""Roofline estimator tests: invariants the perf pass relies on."""

import pytest

from compile import roofline


def test_all_kernels_fit_vmem():
    for e in roofline.all_estimates():
        assert e.vmem_frac < 0.5, f"{e.name}: {e.vmem_frac:.2f} of VMEM"
        assert e.vmem_bytes > 0


def test_dgemm_becomes_compute_bound_with_bigger_tiles():
    # At the artifact size with 128-tiles the A/B re-reads leave DGEMM
    # HBM-bound; the perf-pass remedy is bigger output tiles (fewer
    # re-reads) and a deeper K block (less drain): 512-tiles at 2048^3 tip
    # it over the ridge while staying well inside VMEM.
    small = roofline.dgemm_estimate(256, 256, 256)
    assert small.bound == "memory"
    big = roofline.dgemm_estimate(2048, 2048, 2048, bm=512, bn=512, bk=512)
    assert big.bound == "compute"
    assert big.vmem_frac < 0.5
    ests = {e.name: e for e in roofline.all_estimates()}
    assert ests["stream"].bound == "memory"
    assert ests["dgemm"].arithmetic_intensity > 10 * ests["stream"].arithmetic_intensity


def test_mxu_utilization_monotone_in_tile_size():
    full = roofline.dgemm_estimate(1024, 1024, 1024, bm=128, bn=128, bk=128)
    half = roofline.dgemm_estimate(1024, 1024, 1024, bm=64, bn=64, bk=128)
    assert full.mxu_utilization > half.mxu_utilization


def test_bigger_k_block_improves_drain():
    small = roofline.dgemm_estimate(1024, 1024, 1024, bk=128)
    large = roofline.dgemm_estimate(1024, 1024, 1024, bk=512)
    assert large.mxu_utilization > small.mxu_utilization
    # But VMEM grows.
    assert large.vmem_bytes > small.vmem_bytes


def test_estimated_times_positive_and_finite():
    for e in roofline.all_estimates():
        assert e.est_step_seconds > 0
        assert e.est_step_seconds < 1.0, f"{e.name} absurdly slow: {e}"


def test_stream_lane_alignment_matters():
    aligned = roofline.stream_estimate(64, 4096, brows=8, blanes=1024)
    misaligned = roofline.stream_estimate(64, 4096, brows=4, blanes=64)
    assert aligned.mxu_utilization >= misaligned.mxu_utilization


def test_report_renders_every_kernel():
    r = roofline.report()
    for name in ["dgemm", "stream", "minife", "fft", "ring"]:
        assert name in r


@pytest.mark.parametrize("n", [256, 1024, 4096])
def test_fft_estimate_scales(n):
    e = roofline.fft_estimate(n)
    assert e.flops_per_step == 10 * n * (n.bit_length() - 1)
