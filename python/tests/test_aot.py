"""AOT pipeline tests: lowering determinism, manifest integrity, HLO sanity."""

import hashlib
import json
import pathlib

import pytest

from compile import aot
from compile.model import SPECS

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.mark.parametrize("name", list(SPECS))
def test_lowering_produces_hlo_text(name):
    text = aot.lower_spec(name)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # The rust-side loader requires the text parser path; serialized protos
    # from jax>=0.5 would not survive xla_extension 0.5.1 (64-bit ids).
    assert "\x00" not in text


@pytest.mark.parametrize("name", list(SPECS))
def test_lowering_is_deterministic(name):
    assert aot.lower_spec(name) == aot.lower_spec(name)


def test_arg_manifest_shapes():
    for spec in SPECS.values():
        man = aot.arg_manifest(spec)
        assert len(man) == len(spec.args)
        for entry, arg in zip(man, spec.args):
            assert tuple(entry["shape"]) == arg.shape
            assert entry["dtype"] == arg.dtype.name


@pytest.mark.skipif(
    not (ARTIFACTS / "manifest.json").exists(),
    reason="run `make artifacts` first",
)
def test_artifacts_match_manifest():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    assert set(manifest) == set(SPECS)
    for name, entry in manifest.items():
        text = (ARTIFACTS / entry["hlo"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == entry["sha256"]
        assert entry["profile"] == SPECS[name].profile
        assert entry["flops_per_step"] == SPECS[name].flops
