"""Kernel vs ref allclose — the CORE correctness signal.

Fixed-shape smoke checks for every L1 kernel; the hypothesis sweeps live in
the per-kernel test modules (test_dgemm.py, test_stream.py, ...).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import dgemm, fft, ref, ring, stencil, stream


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


def test_dgemm_matches_ref():
    a = _rand(0, (256, 128))
    b = _rand(1, (128, 384))
    out = dgemm.dgemm(a, b)
    np.testing.assert_allclose(out, ref.dgemm(a, b), rtol=1e-5, atol=1e-4)


def test_triad_matches_ref():
    b = _rand(2, (16, 2048))
    c = _rand(3, (16, 2048))
    out = stream.triad(b, c, 3.0, brows=8, blanes=1024)
    np.testing.assert_allclose(out, ref.triad(b, c, 3.0), rtol=1e-5, atol=1e-6)


def test_stencil_matches_ref():
    x = _rand(4, (16, 12, 20))
    out = stencil.stencil_matvec(x, bz=4)
    np.testing.assert_allclose(out, ref.stencil_matvec(x), rtol=1e-5, atol=1e-5)


def test_ring_matches_ref():
    buf = _rand(5, (16, 512))
    perm = jnp.roll(jnp.arange(16, dtype=jnp.int32), 1)
    out = ring.ring_exchange(buf, perm)
    np.testing.assert_allclose(out, ref.ring_exchange(buf, perm), rtol=1e-6)


def test_butterfly_matches_ref():
    args = [_rand(10 + i, (64, 4)) for i in range(4)]
    w = [_rand(20 + i, (64, 1)) for i in range(2)]
    outs = fft.butterfly(*args, *w)
    expect = ref.butterfly(*args, *w)
    for o, e in zip(outs, expect):
        np.testing.assert_allclose(o, e, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bad_shape", [(100, 128), (128, 100)])
def test_dgemm_rejects_untileable(bad_shape):
    a = jnp.zeros(bad_shape)
    b = jnp.zeros((bad_shape[1], 128))
    with pytest.raises(ValueError):
        dgemm.dgemm(a, b)


def test_dgemm_rejects_mismatched_inner():
    with pytest.raises(ValueError):
        dgemm.dgemm(jnp.zeros((128, 128)), jnp.zeros((256, 128)))


def test_triad_rejects_mismatch():
    with pytest.raises(ValueError):
        stream.triad(jnp.zeros((8, 1024)), jnp.zeros((8, 2048)), 1.0)


def test_stencil_rejects_untileable():
    with pytest.raises(ValueError):
        stencil.stencil_matvec(jnp.zeros((7, 8, 8)), bz=4)
