"""L2 model step functions: shapes, semantics, and the FFT composition."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


def test_dgemm_step_shape_and_value():
    a = _rand(0, (model.DGEMM_N, model.DGEMM_N))
    b = _rand(1, (model.DGEMM_N, model.DGEMM_N))
    out = model.dgemm_step(a, b)
    assert out.shape == (model.DGEMM_N, model.DGEMM_N)
    np.testing.assert_allclose(out, ref.dgemm(a, b), rtol=1e-4, atol=1e-3)


def test_stream_step():
    b = _rand(2, model.STREAM_SHAPE)
    c = _rand(3, model.STREAM_SHAPE)
    s = jnp.full((1, 1), 1.5)
    out = model.stream_step(b, c, s)
    np.testing.assert_allclose(out, ref.triad(b, c, 1.5), rtol=1e-5, atol=1e-6)


def test_minife_step_is_cg_iteration():
    """One model CG step must equal a hand-rolled CG step on the oracle A."""
    x = jnp.zeros(model.MINIFE_GRID)
    b = _rand(4, model.MINIFE_GRID)
    r = b
    p = r
    x1, r1, p1, rn = model.minife_step(x, r, p)

    ap = ref.stencil_matvec(p)
    alpha = jnp.vdot(r, r) / jnp.vdot(p, ap)
    x_e = x + alpha * p
    r_e = r - alpha * ap
    beta = jnp.vdot(r_e, r_e) / jnp.vdot(r, r)
    p_e = r_e + beta * p

    np.testing.assert_allclose(x1, x_e, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(r1, r_e, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(p1, p_e, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(rn, jnp.sqrt(jnp.vdot(r_e, r_e)), rtol=1e-4)


def test_minife_cg_converges():
    """CG on the SPD stencil operator must reduce the residual monotonically
    (within fp32 noise) over a handful of iterations."""
    b = _rand(5, (16, 16, 16))
    x = jnp.zeros_like(b)
    r = b
    p = r
    norms = [float(jnp.linalg.norm(r))]
    for _ in range(10):
        x, r, p, rn = model.minife_step(x, r, p)
        norms.append(float(rn))
    assert norms[-1] < 0.05 * norms[0], norms


def test_ring_step():
    buf = _rand(6, model.RING_SHAPE)
    perm = jax.random.permutation(
        jax.random.PRNGKey(7), model.RING_SHAPE[0]
    ).astype(jnp.int32)
    out = model.ring_step(buf, perm)
    np.testing.assert_allclose(out, ref.ring_exchange(buf, perm), rtol=1e-6)


@pytest.mark.parametrize("n", [8, 64, 256, 1024])
def test_fft_step_matches_jnp_fft(n):
    re = _rand(8, (n,))
    im = _rand(9, (n,))
    out_re, out_im = model.fft_step(re, im)
    exp_re, exp_im = ref.fft(re, im)
    np.testing.assert_allclose(out_re, exp_re, rtol=1e-3, atol=1e-3 * math.sqrt(n))
    np.testing.assert_allclose(out_im, exp_im, rtol=1e-3, atol=1e-3 * math.sqrt(n))


def test_fft_step_rejects_non_pow2():
    with pytest.raises(ValueError):
        model.fft_step(jnp.zeros(12), jnp.zeros(12))


def test_specs_cover_all_benchmarks():
    assert set(model.SPECS) == {"dgemm", "stream", "minife", "ring", "fft"}
    for name, spec in model.SPECS.items():
        assert spec.name == name
        assert spec.flops > 0 and spec.bytes > 0
        assert spec.profile in {"cpu", "memory", "network", "cpu+memory"}


def test_specs_lowerable():
    """Every spec must trace/lower without executing (AOT precondition)."""
    for spec in model.SPECS.values():
        jax.jit(spec.fn).lower(*spec.args)
