"""Hypothesis sweeps: kernel == oracle across randomized shapes/dtypes/data.

Interpret-mode Pallas is slow, so shapes are kept modest; the point is the
*space* of shapes (tiling edge cases, non-square, minimum sizes), not bulk.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from compile.kernels import dgemm, fft, ref, ring, stencil, stream

COMMON = dict(
    deadline=None,
    max_examples=20,
    suppress_health_check=[HealthCheck.too_slow],
)


def _arr(seed, shape, dtype, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale
    return x.astype(dtype)


@settings(**COMMON)
@given(
    mi=st.integers(1, 4),
    ni=st.integers(1, 4),
    ki=st.integers(1, 4),
    bsz=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_dgemm_property(mi, ni, ki, bsz, seed, dtype):
    m, n, k = mi * bsz, ni * bsz, ki * bsz
    a = _arr(seed, (m, k), dtype)
    b = _arr(seed + 1, (k, n), dtype)
    out = dgemm.dgemm(a, b, bm=bsz, bn=bsz, bk=bsz)
    expect = ref.dgemm(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * k)


@settings(**COMMON)
@given(
    ri=st.integers(1, 4),
    li=st.integers(1, 4),
    scalar=st.floats(-10, 10, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_triad_property(ri, li, scalar, seed):
    shape = (ri * 8, li * 256)
    b = _arr(seed, shape, jnp.float32)
    c = _arr(seed + 1, shape, jnp.float32)
    out = stream.triad(b, c, scalar, brows=8, blanes=256)
    np.testing.assert_allclose(out, ref.triad(b, c, scalar), rtol=1e-5, atol=1e-5)


@settings(**COMMON)
@given(
    zi=st.integers(1, 4),
    ny=st.integers(2, 12),
    nx=st.integers(2, 12),
    bz=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_stencil_property(zi, ny, nx, bz, seed):
    nz = zi * bz
    x = _arr(seed, (nz, ny, nx), jnp.float32)
    out = stencil.stencil_matvec(x, bz=bz)
    np.testing.assert_allclose(out, ref.stencil_matvec(x), rtol=1e-5, atol=1e-5)


@settings(**COMMON)
@given(
    p=st.sampled_from([2, 4, 8, 16, 32]),
    n=st.sampled_from([64, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ring_property(p, n, seed):
    buf = _arr(seed, (p, n), jnp.float32)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), p).astype(jnp.int32)
    out = ring.ring_exchange(buf, perm)
    np.testing.assert_allclose(out, ref.ring_exchange(buf, perm), rtol=1e-6)


@settings(**COMMON)
@given(
    half=st.sampled_from([1, 4, 16, 64]),
    m=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_butterfly_property(half, m, seed):
    ops = [_arr(seed + i, (half, m), jnp.float32) for i in range(4)]
    tw = [_arr(seed + 10 + i, (half, 1), jnp.float32) for i in range(2)]
    outs = fft.butterfly(*ops, *tw)
    expect = ref.butterfly(*ops, *tw)
    for o, e in zip(outs, expect):
        np.testing.assert_allclose(o, e, rtol=1e-5, atol=1e-5)


@settings(**COMMON)
@given(
    p=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ring_preserves_mean(p, seed):
    """Exchange+combine is an averaging step: the global mean is conserved
    when perm is a permutation (doubly-stochastic combine)."""
    buf = _arr(seed, (p, 32), jnp.float32)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), p).astype(jnp.int32)
    out = ring.ring_exchange(buf, perm)
    np.testing.assert_allclose(
        jnp.mean(out), jnp.mean(buf), rtol=1e-4, atol=1e-5
    )
